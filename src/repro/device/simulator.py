"""The tunable "device" that CORAL optimizes — the pod-level analogue of
the paper's Jetson + tegrastats measurement loop (Fig. 2).

``measure`` applies a configuration and returns noisy (throughput, power),
like a real 1-second tegrastats sample; ``exact`` is the noise-free ground
truth used only by ORACLE (exhaustive offline profiling).

The simulator is parameterized by RooflineTerms extracted from the
compiled multi-pod dry-run of a real (arch × shape × mesh) — see
``repro.launch.tune`` — or by synthetic terms in unit tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.space import Config, ConfigSpace
from repro.device.hw import (
    DEFAULT_HW,
    DeviceProfile,
    DriftSchedule,
    DriftState,
    TPUv5eSpec,
)
from repro.device.perfmodel import (
    PerfModel,
    RooflineTerms,
    canon_columns,
    model_roofline_terms,
)
from repro.device.power import PowerModel


class DeviceSimulator:
    def __init__(
        self,
        space: ConfigSpace,
        terms: RooflineTerms,
        hw: TPUv5eSpec = DEFAULT_HW,
        noise: float = 0.02,
        seed: int = 0,
        contention_kappa: float = 0.06,
    ):
        self.space = space
        self.perf = PerfModel(terms, hw, contention_kappa)
        self.power_model = PowerModel(self.perf, hw)
        self.noise = noise
        self.rng = np.random.default_rng(seed)
        self.n_measurements = 0

    def _to_dict(self, config: Config) -> Dict[str, float]:
        from repro.device.perfmodel import canon

        return canon(dict(zip(self.space.names, config)))

    def exact(self, config: Config) -> Tuple[float, float]:
        d = self._to_dict(config)
        return self.perf.throughput(d), self.power_model.power(d)

    def measure(self, config: Config) -> Tuple[float, float]:
        tau, p = self.exact(config)
        self.n_measurements += 1
        if self.noise:
            tau *= 1.0 + self.rng.normal(0.0, self.noise)
            p *= 1.0 + self.rng.normal(0.0, self.noise)
        return max(tau, 1e-9), max(p, 1e-9)

    # ------------------------------------------------------------------
    # Batched sweeps: one numpy evaluation over an (N, D) config matrix
    # instead of N Python calls — what ORACLE / ALERT profiling / the
    # Pareto figures run on.
    # ------------------------------------------------------------------
    def exact_all(
        self, configs: Optional[np.ndarray] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Noise-free (τ, p) arrays for an (N, D) config matrix (defaults
        to the full ``space.grid()``)."""
        if configs is None:
            configs = self.space.grid()
        cols = canon_columns(self.space.names, np.asarray(configs, np.float64))
        tau, util, mem_frac = self.perf.stats_batch(cols)
        return tau, self.power_model.power_batch(cols, util, mem_frac)

    def measure_all(
        self, configs: Optional[np.ndarray] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Noisy batched measurement. Draws the noise as an (N, 2) block in
        config-major order, so the RNG stream — and therefore every
        downstream selection — matches N sequential ``measure`` calls
        exactly."""
        if configs is None:
            configs = self.space.grid()
        tau, p = self.exact_all(configs)
        self.n_measurements += tau.size
        if self.noise:
            z = self.rng.normal(0.0, self.noise, size=(tau.size, 2))
            tau = tau * (1.0 + z[:, 0])
            p = p * (1.0 + z[:, 1])
        return np.maximum(tau, 1e-9), np.maximum(p, 1e-9)


class DriftingSimulator:
    """A time-varying device twin: the wrapped simulator's delivered
    clocks, host speed, stream contention and static power follow a
    ``DriftSchedule`` on a control-interval clock.

    ``set_time`` advances the clock; ``exact``/``measure``/``exact_all``
    evaluate at the current interval, so the same object serves both the
    noisy device the optimizer sees and (wrapped around a noise-free
    base) the ground-truth twin that scores it — including the post-shift
    oracle, which is just ``set_time(t_end)`` + the usual batched sweep.

    Drift semantics (see ``repro.device.hw.DriftState``):
      - thermal throttling reduces the *delivered* clock by
        ``derate · f_rel`` of itself — quadratic in the requested level,
        so high DVFS points lose disproportionately more throughput;
      - dynamic power still follows the *requested* DVFS point (the
        governor throttles by duty-cycling, the rail voltage stays
        commanded) while static power inflates with temperature —
        post-shift, racing the clock costs the same watts for less τ;
      - a co-tenant inflates host time and per-stream DRAM contention;
      - ``budget_scale`` is carried but not applied here: budgets are an
        external constraint, the control loop reads them off the schedule.
    """

    def __init__(self, base: DeviceSimulator, schedule: DriftSchedule):
        self.base = base
        self.space = base.space
        self.schedule = schedule
        self.noise = base.noise
        self.rng = base.rng
        self.n_measurements = 0
        self.t = 0
        self._state = schedule.state_at(0)
        self._models: Dict[Tuple[float, float], Tuple[PerfModel, PowerModel]] = {}

    def set_time(self, t: int) -> None:
        self.t = int(t)
        self._state = self.schedule.state_at(self.t)

    @property
    def state(self) -> DriftState:
        return self._state

    def _drifted_models(
        self, state: DriftState
    ) -> Tuple[PerfModel, PowerModel]:
        key = (state.host_inflation, state.kappa_add)
        if key not in self._models:
            base_perf = self.base.perf
            terms = dataclasses.replace(
                base_perf.terms,
                t_host=base_perf.terms.t_host * (1.0 + state.host_inflation),
            )
            perf = PerfModel(
                terms,
                base_perf.hw,
                base_perf.contention_kappa + state.kappa_add,
            )
            self._models[key] = (perf, PowerModel(perf, base_perf.hw))
        return self._models[key]

    def _idle_power(self) -> float:
        hw = self.base.perf.hw
        n = self.base.perf.terms.n_chips
        n_hosts = max(n // hw.chips_per_host, 1)
        return n * hw.p_idle_chip + n_hosts * hw.p_host_idle

    def exact_all(
        self, configs: Optional[np.ndarray] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Noise-free (τ, p) arrays at the current drift clock."""
        if configs is None:
            configs = self.space.grid()
        grid = np.asarray(configs, np.float64)
        cols = canon_columns(self.space.names, grid)
        state = self._state
        perf, power_model = self._drifted_models(state)
        hw = perf.hw
        f_rel = cols["tpu_freq"] / hw.nominal_tpu_freq
        m_rel = cols["hbm_freq"] / hw.nominal_hbm_freq
        delivered = dict(cols)
        delivered["tpu_freq"] = cols["tpu_freq"] * (
            1.0 - state.clock_derate * f_rel
        )
        delivered["hbm_freq"] = cols["hbm_freq"] * (
            1.0 - state.mem_derate * m_rel
        )
        tau, util, mem_frac = perf.stats_batch(delivered)
        p = power_model.power_batch(cols, util, mem_frac)
        p = p + state.static_inflation * self._idle_power()
        return tau, p

    def landscapes(
        self, intervals: int, configs: Optional[np.ndarray] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Stacked noise-free (τ, p) landscapes for intervals 0..T-1:
        two (T, N) float64 arrays, row t bitwise-equal to ``set_time(t)``
        + ``exact_all``. Drift schedules are piecewise constant (a ramp
        holds after ``duration`` intervals), so the sweep runs once per
        *unique* ``DriftState`` and rows are fanned back out — the
        array-native replacement for per-interval ``set_time`` round
        trips in both the compiled episode engine and post-shift
        scoring. The drift clock is restored afterwards."""
        t_saved = self.t
        states = [self.schedule.state_at(t) for t in range(intervals)]
        unique: Dict[DriftState, int] = {}
        rows = np.empty(intervals, np.int64)
        taus, ps = [], []
        try:
            for t, s in enumerate(states):
                if s not in unique:
                    unique[s] = len(taus)
                    self.t = t
                    self._state = s
                    tau, p = self.exact_all(configs)
                    taus.append(tau)
                    ps.append(p)
                rows[t] = unique[s]
        finally:
            self.set_time(t_saved)
        return np.stack(taus)[rows], np.stack(ps)[rows]

    def exact(self, config: Config) -> Tuple[float, float]:
        tau, p = self.exact_all(np.asarray([config], np.float64))
        return float(tau[0]), float(p[0])

    def measure(self, config: Config) -> Tuple[float, float]:
        tau, p = self.exact(config)
        self.n_measurements += 1
        if self.noise:
            tau *= 1.0 + self.rng.normal(0.0, self.noise)
            p *= 1.0 + self.rng.normal(0.0, self.noise)
        return max(tau, 1e-9), max(p, 1e-9)

    def measure_all(
        self, configs: Optional[np.ndarray] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        if configs is None:
            configs = self.space.grid()
        tau, p = self.exact_all(configs)
        self.n_measurements += tau.size
        if self.noise:
            z = self.rng.normal(0.0, self.noise, size=(tau.size, 2))
            tau = tau * (1.0 + z[:, 0])
            p = p * (1.0 + z[:, 1])
        return np.maximum(tau, 1e-9), np.maximum(p, 1e-9)


class FaultySimulator:
    """A faulty device twin: a stationary simulator wrapped with realized
    ``FaultTables`` (``core.faults``) on a control-interval clock — the
    fault-family analogue of ``DriftingSimulator``.

    The *device* is stationary; what breaks is everything around it:

      ``measure``  returns the base twin's noisy sample scaled by the
          interval's telemetry-spike factors, or (NaN, NaN) on a sensor
          dropout. The base noise stream still advances on dropped
          intervals (the sample was taken, it just never arrived), so
          fault and fault-free runs stay draw-for-draw aligned — the
          compiled engine's fault tables bake the identical values.
      ``actuate``  models the knob write path: the commanded config takes
          effect only if the interval's failed-attempt count is within
          the caller's retry budget (hardened readback+retry passes
          ``RobustConfig.act_retries``; the blind ablation passes 0),
          otherwise the knob silently sticks at the previous applied
          config. A firmware reset then snaps to the default row (the
          ``max_power`` preset) regardless. Returns the config actually
          in force; ``readback`` re-reads it without side effects.
      ``exact``/``exact_all`` stay the *fault-free* ground truth — what
          the device genuinely does at a config — which is exactly what
          oracle scoring must use.
    """

    def __init__(self, base: DeviceSimulator, tables):
        self.base = base
        self.space = base.space
        self.tables = tables
        self.noise = base.noise
        self.rng = base.rng
        self.n_measurements = 0
        self.t = 0
        # a rebooted device comes up on its firmware default row
        self._applied = self.space.preset("max_power")

    def set_time(self, t: int) -> None:
        self.t = int(t)

    @property
    def pod_down(self) -> bool:
        """True while the edge→pod link outage is active (serving layer)."""
        return bool(self.tables.pod_out[self.t])

    def actuate(self, config: Config, retries: int = 0) -> Config:
        """Attempt to apply ``config`` with ``retries`` extra attempts;
        returns the config actually in force afterwards."""
        if int(self.tables.stick[self.t]) <= int(retries):
            self._applied = tuple(config)
        if bool(self.tables.reset[self.t]):
            self._applied = self.space.preset("max_power")
        return self._applied

    def readback(self) -> Config:
        return self._applied

    def exact(self, config: Config) -> Tuple[float, float]:
        return self.base.exact(config)

    def exact_all(
        self, configs: Optional[np.ndarray] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        return self.base.exact_all(configs)

    def measure(self, config: Config) -> Tuple[float, float]:
        tau, p = self.base.measure(config)
        self.n_measurements += 1
        t = self.t
        tau *= float(self.tables.spike[t, 0])
        p *= float(self.tables.spike[t, 1])
        if bool(self.tables.drop[t]):
            return float("nan"), float("nan")
        return tau, p


def synthetic_terms(kind: str = "balanced", n_chips: int = 256) -> RooflineTerms:
    """Workload stand-ins for tests/examples before a dry-run exists."""
    kinds = {
        # t_compute, t_memory, t_collective, t_host, items_per_step
        "balanced": (8e-3, 6e-3, 2e-3, 2.5e-3, 256.0),
        "compute_bound": (20e-3, 5e-3, 2e-3, 2.0e-3, 256.0),
        "memory_bound": (2e-3, 18e-3, 1e-3, 2.0e-3, 128.0),
        "collective_bound": (3e-3, 4e-3, 12e-3, 2.0e-3, 32.0),
        "host_bound": (2e-3, 2e-3, 1e-3, 12e-3, 64.0),
    }
    t = kinds[kind]
    return RooflineTerms(*t[:4], items_per_step=t[4], n_chips=n_chips)


def build_cell_simulator(
    profile: DeviceProfile,
    model_cfg,
    kind: str = "decode",
    batch: int = 8,
    seq: int = 256,
    noise: float = 0.02,
    seed: int = 0,
) -> "DeviceSimulator":
    """Simulator for one (device profile × model × workload-kind) cell.

    The profile supplies the knob grid, power curve and derating; the
    model config supplies the FLOP/byte footprint (its analytic active
    parameter count) — see ``model_roofline_terms``. This replaces the
    hand-wired single device per script with a constructor the scenario
    matrix can call for every cell.
    """
    terms = model_roofline_terms(model_cfg, profile, kind=kind, batch=batch, seq=seq)
    return DeviceSimulator(
        profile.space(),
        terms,
        profile.hw,
        noise=noise,
        seed=seed,
        contention_kappa=profile.contention_kappa,
    )


def jetson_like_simulator(
    space: ConfigSpace, model_scale: float = 1.0, seed: int = 0, noise: float = 0.02
) -> "DeviceSimulator":
    """A single-device (n_chips=1) simulator with Jetson-like magnitudes for
    the paper-figure benchmarks: throughput in fps, power in watts.

    ``model_scale`` scales compute/memory time (YOLO≈1, FRCNN≈6, RETINANET≈12
    — the paper's 20× parameter span maps to roughly this step-time span).
    """
    from repro.device.hw import TPUv5eSpec

    hw = TPUv5eSpec(
        name="jetson-like",
        nominal_tpu_freq=space.dims[2].hi,
        nominal_hbm_freq=space.dims[3].hi,
        nominal_host_freq=space.dims[0].hi,
        p_idle_chip=2.2,
        p_dyn_chip=4.5,
        p_hbm_chip=1.2,
        chips_per_host=1,
        p_host_idle=1.0,
        p_host_core=0.35,
    )
    terms = RooflineTerms(
        t_compute=12e-3 * model_scale,
        t_memory=7e-3 * model_scale,
        t_collective=0.0,
        t_host=16e-3,  # CPU preprocessing dominates on Jetson-class hosts
        items_per_step=1.0,
        n_chips=1,
    )
    return DeviceSimulator(space, terms, hw, noise=noise, seed=seed,
                           contention_kappa=0.05)
