from repro.device.hw import DEFAULT_HW, TPUv5eSpec  # noqa: F401
from repro.device.perfmodel import PerfModel, RooflineTerms  # noqa: F401
from repro.device.power import PowerModel  # noqa: F401
from repro.device.simulator import (  # noqa: F401
    DeviceSimulator,
    jetson_like_simulator,
    synthetic_terms,
)
