from repro.device.hw import (  # noqa: F401
    DEFAULT_HW,
    DEVICE_PROFILES,
    DeviceProfile,
    TPUv5eSpec,
    get_profile,
)
from repro.device.perfmodel import (  # noqa: F401
    PerfModel,
    RooflineTerms,
    model_roofline_terms,
)
from repro.device.power import PowerModel  # noqa: F401
from repro.device.simulator import (  # noqa: F401
    DeviceSimulator,
    build_cell_simulator,
    jetson_like_simulator,
    synthetic_terms,
)
