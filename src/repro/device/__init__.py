from repro.device.hw import (  # noqa: F401
    DEFAULT_HW,
    DEVICE_PROFILES,
    NO_DRIFT,
    BudgetStep,
    CotenantStep,
    DeviceProfile,
    DriftSchedule,
    DriftState,
    ThermalRamp,
    TPUv5eSpec,
    get_profile,
)
from repro.device.perfmodel import (  # noqa: F401
    PerfModel,
    RooflineTerms,
    model_roofline_terms,
)
from repro.device.cotenant import CotenantSimulator  # noqa: F401
from repro.device.factory import build_twin  # noqa: F401
from repro.device.power import PowerModel  # noqa: F401
from repro.device.simulator import (  # noqa: F401
    DeviceSimulator,
    DriftingSimulator,
    FaultySimulator,
    build_cell_simulator,
    jetson_like_simulator,
    synthetic_terms,
)
