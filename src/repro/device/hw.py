"""Hardware constants for the target platform (TPU v5e pod) and the
DVFS-style scaling model. These are the same constants the roofline
analysis uses (system prompt / EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TPUv5eSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12  # per chip, at nominal clock
    hbm_bw: float = 819e9  # B/s per chip, at nominal HBM clock
    ici_bw: float = 50e9  # B/s per link
    hbm_per_chip: float = 16e9  # bytes
    nominal_tpu_freq: float = 940.0  # MHz — knob reference point
    nominal_hbm_freq: float = 2665.0  # MHz — knob reference point
    # power model (per chip) — plausible v5e-class numbers; the *structure*
    # (static + dynamic·f³ + HBM term) is what CORAL exploits, as on Jetson.
    p_idle_chip: float = 60.0  # W
    p_dyn_chip: float = 120.0  # W at nominal clock, full utilization
    p_hbm_chip: float = 30.0  # W at nominal HBM clock, fully streaming
    # host (per pod-slice host, 1 host per 8 chips on v5e)
    chips_per_host: int = 8
    p_host_idle: float = 90.0  # W
    p_host_core: float = 9.0  # W per active core at nominal host clock
    nominal_host_freq: float = 2600.0  # MHz


DEFAULT_HW = TPUv5eSpec()
