"""Hardware constants and the device-profile registry.

``TPUv5eSpec`` holds one accelerator's DVFS/power constants (the same
constants the roofline analysis uses — EXPERIMENTS.md §Roofline). A
``DeviceProfile`` bundles a spec with the knob grid it exposes and the
efficiency/contention parameters needed to turn a model's FLOP/byte
footprint into ``RooflineTerms`` — the unit the scenario matrix
enumerates over (the paper's "Xavier NX vs Orin Nano" axis).
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class TPUv5eSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12  # per chip, at nominal clock
    hbm_bw: float = 819e9  # B/s per chip, at nominal HBM clock
    ici_bw: float = 50e9  # B/s per link
    hbm_per_chip: float = 16e9  # bytes
    nominal_tpu_freq: float = 940.0  # MHz — knob reference point
    nominal_hbm_freq: float = 2665.0  # MHz — knob reference point
    # power model (per chip) — plausible v5e-class numbers; the *structure*
    # (static + dynamic·f³ + HBM term) is what CORAL exploits, as on Jetson.
    p_idle_chip: float = 60.0  # W
    p_dyn_chip: float = 120.0  # W at nominal clock, full utilization
    p_hbm_chip: float = 30.0  # W at nominal HBM clock, fully streaming
    # host (per pod-slice host, 1 host per 8 chips on v5e)
    chips_per_host: int = 8
    p_host_idle: float = 90.0  # W
    p_host_core: float = 9.0  # W per active core at nominal host clock
    nominal_host_freq: float = 2600.0  # MHz


DEFAULT_HW = TPUv5eSpec()


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """One deployable target: accelerator spec + knob grid + derating.

    ``compute_eff``/``mem_eff`` are the achievable fractions of peak
    FLOP/s and DRAM bandwidth for dense inference (MXU/tensor-core
    utilization and streaming efficiency); ``t_host_per_item`` is the
    host-side preprocess/dispatch cost per inference item at nominal
    host clocks. Together with a model's analytic FLOP/byte footprint
    (``ModelConfig.flops_per_token``/``bytes_per_token``) they produce
    the per-(device, model) ``RooflineTerms`` the simulator runs on —
    see ``repro.device.perfmodel.model_roofline_terms``.
    """

    name: str
    hw: TPUv5eSpec
    space_kind: str  # key understood by ``space()``
    n_chips: int = 1
    t_host_per_item: float = 2.5e-3  # s per item at nominal host clocks
    contention_kappa: float = 0.05  # DRAM contention per extra stream
    compute_eff: float = 0.45
    mem_eff: float = 0.70

    def space(self):
        """The profile's DVFS knob grid (its ``ConfigSpace``)."""
        from repro.core.space import profile_space

        return profile_space(self.space_kind)


# Two heterogeneous edge profiles (the paper's Jetson pair analogue:
# different DVFS ladders — see ``profile_space`` — different peak
# FLOP/s, DRAM bandwidth and power curves) plus the pod target. Nominal
# clocks are each grid's top step so f_rel ≤ 1 on every knob. The power
# split is dynamic-dominated (idle is a small fraction of load power, as
# on real Jetson power rails): that is what makes "meet the target at
# low clocks" more efficient than racing to idle, i.e. what gives the
# matrix's τ-targeted regimes a non-trivial optimum.
EDGE_XAVIER_NX = DeviceProfile(
    name="edge-xavier-nx",
    hw=TPUv5eSpec(
        name="xavier-nx",
        peak_flops_bf16=1.69e12,  # Volta-class fp16
        hbm_bw=59.7e9,
        hbm_per_chip=8e9,
        nominal_tpu_freq=1010.0,
        nominal_hbm_freq=1866.0,
        nominal_host_freq=1890.0,
        p_idle_chip=1.0,
        p_dyn_chip=6.0,
        p_hbm_chip=2.5,  # LPDDR4x streaming draw is a first-class term
        chips_per_host=1,
        p_host_idle=0.5,
        p_host_core=0.35,
    ),
    space_kind="edge_xavier_nx",
    t_host_per_item=1.5e-3,
    contention_kappa=0.03,
    compute_eff=0.45,
    mem_eff=0.70,
)

EDGE_ORIN_NANO = DeviceProfile(
    name="edge-orin-nano",
    hw=TPUv5eSpec(
        name="orin-nano",
        peak_flops_bf16=1.28e12,  # Ampere-class fp16 at lower clocks
        hbm_bw=68.0e9,
        hbm_per_chip=8e9,
        nominal_tpu_freq=624.0,
        nominal_hbm_freq=3199.0,
        nominal_host_freq=1506.0,
        p_idle_chip=0.8,
        p_dyn_chip=4.0,
        p_hbm_chip=2.0,
        chips_per_host=1,
        p_host_idle=0.4,
        p_host_core=0.25,
    ),
    space_kind="edge_orin_nano",
    t_host_per_item=1.8e-3,
    contention_kappa=0.02,
    compute_eff=0.40,
    mem_eff=0.75,
)

POD_V5E = DeviceProfile(
    name="pod-v5e",
    hw=DEFAULT_HW,
    space_kind="tpu_pod",
    n_chips=256,
    t_host_per_item=0.1e-3,
    contention_kappa=0.06,
    compute_eff=0.50,
    mem_eff=0.80,
)

DEVICE_PROFILES: Dict[str, DeviceProfile] = {
    p.name: p for p in (EDGE_XAVIER_NX, EDGE_ORIN_NANO, POD_V5E)
}


def get_profile(name: str) -> DeviceProfile:
    if name not in DEVICE_PROFILES:
        raise KeyError(
            f"unknown device profile {name!r}; known: {sorted(DEVICE_PROFILES)}"
        )
    return DEVICE_PROFILES[name]
