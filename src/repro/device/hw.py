"""Hardware constants, the device-profile registry, and drift schedules.

``TPUv5eSpec`` holds one accelerator's DVFS/power constants (the same
constants the roofline analysis uses — EXPERIMENTS.md §Roofline). A
``DeviceProfile`` bundles a spec with the knob grid it exposes and the
efficiency/contention parameters needed to turn a model's FLOP/byte
footprint into ``RooflineTerms`` — the unit the scenario matrix
enumerates over (the paper's "Xavier NX vs Orin Nano" axis).

``DriftSchedule`` describes how a device's operating conditions change
over a run: thermal-throttle ramps (per-level clock derating plus
static-power inflation), co-tenant interference steps (host slowdown and
extra per-stream memory contention), and power-budget steps. A schedule
is a pure function of the control-interval clock ``t`` — the same
declarative shape the scenario matrix uses for everything else — and is
applied to a simulator by ``repro.device.simulator.DriftingSimulator``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TPUv5eSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12  # per chip, at nominal clock
    hbm_bw: float = 819e9  # B/s per chip, at nominal HBM clock
    ici_bw: float = 50e9  # B/s per link
    hbm_per_chip: float = 16e9  # bytes
    nominal_tpu_freq: float = 940.0  # MHz — knob reference point
    nominal_hbm_freq: float = 2665.0  # MHz — knob reference point
    # power model (per chip) — plausible v5e-class numbers; the *structure*
    # (static + dynamic·f³ + HBM term) is what CORAL exploits, as on Jetson.
    p_idle_chip: float = 60.0  # W
    p_dyn_chip: float = 120.0  # W at nominal clock, full utilization
    p_hbm_chip: float = 30.0  # W at nominal HBM clock, fully streaming
    # host (per pod-slice host, 1 host per 8 chips on v5e)
    chips_per_host: int = 8
    p_host_idle: float = 90.0  # W
    p_host_core: float = 9.0  # W per active core at nominal host clock
    nominal_host_freq: float = 2600.0  # MHz


DEFAULT_HW = TPUv5eSpec()


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """One deployable target: accelerator spec + knob grid + derating.

    ``compute_eff``/``mem_eff`` are the achievable fractions of peak
    FLOP/s and DRAM bandwidth for dense inference (MXU/tensor-core
    utilization and streaming efficiency); ``t_host_per_item`` is the
    host-side preprocess/dispatch cost per inference item at nominal
    host clocks. Together with a model's analytic FLOP/byte footprint
    (``ModelConfig.flops_per_token``/``bytes_per_token``) they produce
    the per-(device, model) ``RooflineTerms`` the simulator runs on —
    see ``repro.device.perfmodel.model_roofline_terms``.
    """

    name: str
    hw: TPUv5eSpec
    space_kind: str  # key understood by ``space()``
    n_chips: int = 1
    t_host_per_item: float = 2.5e-3  # s per item at nominal host clocks
    contention_kappa: float = 0.05  # DRAM contention per extra stream
    compute_eff: float = 0.45
    mem_eff: float = 0.70

    def space(self):
        """The profile's DVFS knob grid (its ``ConfigSpace``)."""
        from repro.core.space import profile_space

        return profile_space(self.space_kind)


# Two heterogeneous edge profiles (the paper's Jetson pair analogue:
# different DVFS ladders — see ``profile_space`` — different peak
# FLOP/s, DRAM bandwidth and power curves) plus the pod target. Nominal
# clocks are each grid's top step so f_rel ≤ 1 on every knob. The power
# split is dynamic-dominated (idle is a small fraction of load power, as
# on real Jetson power rails): that is what makes "meet the target at
# low clocks" more efficient than racing to idle, i.e. what gives the
# matrix's τ-targeted regimes a non-trivial optimum.
EDGE_XAVIER_NX = DeviceProfile(
    name="edge-xavier-nx",
    hw=TPUv5eSpec(
        name="xavier-nx",
        peak_flops_bf16=1.69e12,  # Volta-class fp16
        hbm_bw=59.7e9,
        hbm_per_chip=8e9,
        nominal_tpu_freq=1010.0,
        nominal_hbm_freq=1866.0,
        nominal_host_freq=1890.0,
        p_idle_chip=1.0,
        p_dyn_chip=6.0,
        p_hbm_chip=2.5,  # LPDDR4x streaming draw is a first-class term
        chips_per_host=1,
        p_host_idle=0.5,
        p_host_core=0.35,
    ),
    space_kind="edge_xavier_nx",
    t_host_per_item=1.5e-3,
    contention_kappa=0.03,
    compute_eff=0.45,
    mem_eff=0.70,
)

EDGE_ORIN_NANO = DeviceProfile(
    name="edge-orin-nano",
    hw=TPUv5eSpec(
        name="orin-nano",
        peak_flops_bf16=1.28e12,  # Ampere-class fp16 at lower clocks
        hbm_bw=68.0e9,
        hbm_per_chip=8e9,
        nominal_tpu_freq=624.0,
        nominal_hbm_freq=3199.0,
        nominal_host_freq=1506.0,
        p_idle_chip=0.8,
        p_dyn_chip=4.0,
        p_hbm_chip=2.0,
        chips_per_host=1,
        p_host_idle=0.4,
        p_host_core=0.25,
    ),
    space_kind="edge_orin_nano",
    t_host_per_item=1.8e-3,
    contention_kappa=0.02,
    compute_eff=0.40,
    mem_eff=0.75,
)

# Orin NX class: same Ampere family as the Nano but a faster ladder in
# every dimension (more SMs, LPDDR5 at higher clocks, beefier host).
# Like the Nano — and unlike Xavier NX, whose efficiency optimum sits in
# the corner of a τ plateau — its efficiency optimum is *interior* to
# the DVFS grid, which is what makes it drift-sensitive: thermal or
# co-tenant derating genuinely reorders its configurations, so it is one
# of the two devices the dynamic (drift) scenario cells run on.
EDGE_ORIN_NX = DeviceProfile(
    name="edge-orin-nx",
    hw=TPUv5eSpec(
        name="orin-nx",
        peak_flops_bf16=1.88e12,
        hbm_bw=102.4e9,
        hbm_per_chip=16e9,
        nominal_tpu_freq=918.0,
        nominal_hbm_freq=3733.0,
        nominal_host_freq=1984.0,
        p_idle_chip=1.0,
        p_dyn_chip=5.5,
        p_hbm_chip=2.8,
        chips_per_host=1,
        p_host_idle=0.5,
        p_host_core=0.3,
    ),
    space_kind="edge_orin_nx",
    t_host_per_item=1.6e-3,
    contention_kappa=0.025,
    compute_eff=0.42,
    mem_eff=0.72,
)

POD_V5E = DeviceProfile(
    name="pod-v5e",
    hw=DEFAULT_HW,
    space_kind="tpu_pod",
    n_chips=256,
    t_host_per_item=0.1e-3,
    contention_kappa=0.06,
    compute_eff=0.50,
    mem_eff=0.80,
)

DEVICE_PROFILES: Dict[str, DeviceProfile] = {
    p.name: p
    for p in (EDGE_XAVIER_NX, EDGE_ORIN_NANO, EDGE_ORIN_NX, POD_V5E)
}


def get_profile(name: str) -> DeviceProfile:
    """Look up a device profile by registry name (KeyError lists the
    known names)."""
    if name not in DEVICE_PROFILES:
        raise KeyError(
            f"unknown device profile {name!r}; known: {sorted(DEVICE_PROFILES)}"
        )
    return DEVICE_PROFILES[name]


# ---------------------------------------------------------------------------
# Non-stationary operating conditions: drift schedules
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DriftState:
    """The device's operating condition at one control interval.

    ``clock_derate``/``mem_derate`` are the fractional loss of *delivered*
    accelerator/memory clock at the top DVFS level (throttling scales
    quadratically with the requested level, so racing the clock loses more
    than idling at the bottom of the ladder — the per-level shape real
    thermal governors produce). ``static_inflation`` inflates the idle
    power draw (hot silicon leaks more). ``host_inflation`` and
    ``kappa_add`` model a co-tenant stealing host cycles and DRAM
    bandwidth. ``budget_scale`` rescales the external power budget — a
    commanded change, not a device property, so the control loop reads it
    from the schedule rather than detecting it.
    """

    clock_derate: float = 0.0
    mem_derate: float = 0.0
    static_inflation: float = 0.0
    host_inflation: float = 0.0
    kappa_add: float = 0.0
    budget_scale: float = 1.0

    @property
    def stationary(self) -> bool:
        return self == DRIFT_NONE


DRIFT_NONE = DriftState()


@dataclasses.dataclass(frozen=True)
class ThermalRamp:
    """Thermal throttling: derating ramps linearly over ``duration``
    intervals starting at ``start`` and then holds."""

    start: int
    duration: int = 6
    clock_derate: float = 0.30
    mem_derate: float = 0.15
    static_inflation: float = 0.30

    def state_at(self, t: int) -> DriftState:
        ramp = min(max((t - self.start) / max(self.duration, 1), 0.0), 1.0)
        return DriftState(
            clock_derate=ramp * self.clock_derate,
            mem_derate=ramp * self.mem_derate,
            static_inflation=ramp * self.static_inflation,
        )

    @property
    def end(self) -> int:
        return self.start + self.duration


@dataclasses.dataclass(frozen=True)
class CotenantStep:
    """A co-located job lands at ``start`` (and leaves at ``until`` if
    set): host preprocessing slows down, per-stream DRAM contention
    rises, and the co-tenant's own draw shows up on the shared power
    rail — the Fulcrum concurrent-workload setting."""

    start: int
    host_inflation: float = 0.8
    kappa_add: float = 0.12
    static_inflation: float = 0.0  # co-tenant draw, as a fraction of idle
    until: Optional[int] = None

    def state_at(self, t: int) -> DriftState:
        active = t >= self.start and (self.until is None or t < self.until)
        if not active:
            return DRIFT_NONE
        return DriftState(
            host_inflation=self.host_inflation,
            kappa_add=self.kappa_add,
            static_inflation=self.static_inflation,
        )

    @property
    def end(self) -> int:
        return self.start


@dataclasses.dataclass(frozen=True)
class BudgetStep:
    """The external power budget is rescaled at ``start`` — an operator
    command (e.g. battery-saver or a rack-level cap), carried on the same
    drift clock so the control loop sees it at the interval it lands."""

    start: int
    scale: float = 0.8

    def state_at(self, t: int) -> DriftState:
        if t < self.start:
            return DRIFT_NONE
        return DriftState(budget_scale=self.scale)

    @property
    def end(self) -> int:
        return self.start


DriftEvent = object  # ThermalRamp | CotenantStep | BudgetStep


@dataclasses.dataclass(frozen=True)
class DriftSchedule:
    """A set of drift events composed over the control-interval clock.

    Additive terms (derates, inflations, contention) sum and clip;
    ``budget_scale`` factors multiply. ``shift_start``/``shift_end``
    bracket the non-stationary transient for scoring (recovery windows
    are measured from ``shift_start``; "fully shifted" means
    ``t >= shift_end``).
    """

    events: Tuple[DriftEvent, ...] = ()

    def state_at(self, t: int) -> DriftState:
        clock = mem = static = host = kappa = 0.0
        budget = 1.0
        for ev in self.events:
            s = ev.state_at(t)
            clock += s.clock_derate
            mem += s.mem_derate
            static += s.static_inflation
            host += s.host_inflation
            kappa += s.kappa_add
            budget *= s.budget_scale
        return DriftState(
            clock_derate=min(clock, 0.9),
            mem_derate=min(mem, 0.9),
            static_inflation=static,
            host_inflation=host,
            kappa_add=kappa,
            budget_scale=budget,
        )

    @property
    def shift_start(self) -> int:
        return min((ev.start for ev in self.events), default=0)

    @property
    def shift_end(self) -> int:
        return max((ev.end for ev in self.events), default=0)

    def states_stacked(self, intervals: int) -> Dict[str, "object"]:
        """Per-interval drift vectors for a compiled control loop.

        Returns (intervals,)-shaped float64 numpy arrays of every
        ``DriftState`` field — ``state_at(t)`` evaluated once per
        interval up front, so a ``lax.scan`` episode body (and the
        batched post-shift scoring) can index arrays instead of calling
        back into Python per interval.
        """
        states = [self.state_at(t) for t in range(intervals)]
        return {
            f.name: np.asarray(
                [getattr(s, f.name) for s in states], np.float64
            )
            for f in dataclasses.fields(DriftState)
        }


NO_DRIFT = DriftSchedule(())


# ---------------------------------------------------------------------------
# Fleet sampling: registry profiles → heterogeneous per-unit twins
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ConstantDerate:
    """A stationary operating-condition offset — the drift-event shape
    with no time dependence. The fleet sampler uses it to model ambient
    temperature: a hot enclosure derates delivered clocks and inflates
    leakage *for the whole run*, so a twin's landscape is built by
    wrapping its simulator in a one-event schedule of this."""

    clock_derate: float = 0.0
    mem_derate: float = 0.0
    static_inflation: float = 0.0
    start: int = 0

    def state_at(self, t: int) -> DriftState:
        return DriftState(
            clock_derate=self.clock_derate,
            mem_derate=self.mem_derate,
            static_inflation=self.static_inflation,
        )

    @property
    def end(self) -> int:
        return 0


@dataclasses.dataclass(frozen=True)
class FleetPerturbation:
    """One fleet unit's deviation from its family's registry profile.

    ``compute_scale``/``mem_scale`` are the silicon lottery on achievable
    FLOP/s and DRAM bandwidth (bin-to-bin MXU/streaming efficiency
    spread); ``host_scale`` speeds or slows host preprocess;
    ``power_scale`` is the leakage/process bin on the power rails;
    ``ambient_derate`` is the stationary thermal derate of the unit's
    enclosure (applied as a ``ConstantDerate`` when building its
    landscape); ``ladder_variant`` selects a firmware DVFS-ladder
    variant — realized as a mask of *locked-out* grid rows (see
    ``repro.experiments.fleet.ladder_banned_rows``), so every variant
    shares its family's ``ConfigSpace`` and compiled constants."""

    family: str
    twin_id: int
    compute_scale: float = 1.0
    mem_scale: float = 1.0
    host_scale: float = 1.0
    power_scale: float = 1.0
    ambient_derate: float = 0.0
    ladder_variant: int = 0

    def ambient(self) -> ConstantDerate:
        """The twin's stationary operating-condition event (thermal
        derate quadratic in the requested level, hotter silicon leaks
        more — the same shape ``ThermalRamp`` holds at, held forever)."""
        return ConstantDerate(
            clock_derate=self.ambient_derate,
            mem_derate=0.5 * self.ambient_derate,
            static_inflation=self.ambient_derate,
        )


def perturbed_profile(pert: FleetPerturbation) -> DeviceProfile:
    """The registry profile scaled to one fleet unit's silicon.

    Efficiency fractions absorb the compute/memory lottery, host time
    the host lottery, and every power-rail constant the leakage bin —
    the knob grid and roofline *structure* stay the family's, which is
    what makes warm-start transfer across neighbors meaningful."""
    base = get_profile(pert.family)
    hw = dataclasses.replace(
        base.hw,
        p_idle_chip=base.hw.p_idle_chip * pert.power_scale,
        p_dyn_chip=base.hw.p_dyn_chip * pert.power_scale,
        p_hbm_chip=base.hw.p_hbm_chip * pert.power_scale,
        p_host_idle=base.hw.p_host_idle * pert.power_scale,
        p_host_core=base.hw.p_host_core * pert.power_scale,
    )
    return dataclasses.replace(
        base,
        name=f"{base.name}#{pert.twin_id:05d}",
        hw=hw,
        compute_eff=base.compute_eff * pert.compute_scale,
        mem_eff=base.mem_eff * pert.mem_scale,
        t_host_per_item=base.t_host_per_item / pert.host_scale,
    )


FLEET_FAMILIES: Tuple[str, ...] = (
    "edge-xavier-nx",
    "edge-orin-nano",
    "edge-orin-nx",
)


def sample_perturbations(
    n: int,
    seed: int,
    families: Sequence[str] = FLEET_FAMILIES,
    n_ladder_variants: int = 3,
) -> Tuple[FleetPerturbation, ...]:
    """``n`` deterministic fleet twins, round-robin across ``families``.

    Twin ``i`` draws from ``default_rng([seed, i])`` — its perturbation
    depends only on (fleet seed, twin id), not on fleet size or sampling
    order, so a 64-twin smoke fleet is exactly the first 64 twins of the
    1024-twin nightly fleet. Scales are clipped mild enough that a
    neighbor's converged optimum stays *near*-optimal, which is the
    regime warm-starting is meant to exploit."""
    out = []
    for i in range(n):
        rng = np.random.default_rng([seed, i])
        out.append(
            FleetPerturbation(
                family=families[i % len(families)],
                twin_id=i,
                compute_scale=float(np.clip(rng.normal(1.0, 0.05), 0.85, 1.15)),
                mem_scale=float(np.clip(rng.normal(1.0, 0.04), 0.88, 1.12)),
                host_scale=float(np.clip(rng.normal(1.0, 0.06), 0.80, 1.20)),
                power_scale=float(np.clip(rng.normal(1.0, 0.06), 0.82, 1.18)),
                ambient_derate=float(rng.uniform(0.0, 0.12)),
                ladder_variant=int(rng.integers(0, n_ladder_variants)),
            )
        )
    return tuple(out)
