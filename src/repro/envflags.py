"""Single shared truthy-parser for the repo's environment flags.

Every boolean-ish env var in the repo (PALLAS_INTERPRET, QUICK,
SERVING_PERF_STRICT, REPRO_CONTRACTS, REPRO_CHECKIFY, ...) routes its
string-to-bool decision through :func:`truthy` so "0"/"false"/"no" mean
the same thing everywhere.  Two wrappers differ only in how an *unset or
empty* variable is treated:

- :func:`parse_flag` — unset falls back to ``default``; an empty string
  is falsy (matches the historical ``benchmarks.common.env_flag``).
- :func:`parse_tristate` — unset or empty means "no opinion" (``None``),
  letting the caller pick a backend-dependent default (matches the
  historical ``PALLAS_INTERPRET`` semantics in the dcov kernel).
"""
from __future__ import annotations

import os
from typing import Optional

# the single source of truth for string falsiness
FALSY = ("", "0", "false", "no")


def truthy(raw: str) -> bool:
    """True unless ``raw`` normalises to one of :data:`FALSY`."""
    return raw.strip().lower() not in FALSY


def parse_flag(raw: Optional[str], default: bool = False) -> bool:
    """Two-state parse: unset -> ``default``, else :func:`truthy`."""
    if raw is None:
        return default
    return truthy(raw)


def parse_tristate(raw: Optional[str]) -> Optional[bool]:
    """Three-state parse: unset/empty -> ``None``, else :func:`truthy`."""
    if raw is None or not raw.strip():
        return None
    return truthy(raw)


def env_flag(name: str, default: bool = False) -> bool:
    """:func:`parse_flag` over ``os.environ[name]``."""
    return parse_flag(os.environ.get(name), default)


def env_tristate(name: str) -> Optional[bool]:
    """:func:`parse_tristate` over ``os.environ[name]``."""
    return parse_tristate(os.environ.get(name))
