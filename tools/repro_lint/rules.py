"""The RL01–RL07 rule implementations.

Every rule is deliberately scoped (see each rule's ``in_scope``) to the
files where its invariant is load-bearing, because repo-specific
heuristics beat generic ones: RL04's dtype discipline matters in the
fixed-size engine state, not in a matplotlib helper. Paths under
tests/lint_fixtures/ are always in scope — that is where the golden
violating snippets live.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from tools.repro_lint.engine import FIXTURE_DIR, Context, Module, Violation


def _is_fixture(relpath: str) -> bool:
    return FIXTURE_DIR in relpath.split("/")


def _dotted(node: ast.AST) -> str:
    """'jax.lax.scan' for Attribute/Name chains, '' otherwise."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


# reading these attributes of a tracer yields static Python metadata, so
# values derived from them are branch-safe inside traced code
_STATIC_ATTRS = ("shape", "ndim", "dtype", "size")


def _value_names(node: ast.AST) -> Set[str]:
    """Names whose traced *value* (not static metadata) flows into
    ``node``: like ``_names_in`` but stops at .shape/.ndim/.dtype/.size
    attribute reads and len() calls."""
    out: Set[str] = set()

    def visit(n: ast.AST) -> None:
        if isinstance(n, ast.Attribute) and n.attr in _STATIC_ATTRS:
            return
        if (
            isinstance(n, ast.Call)
            and isinstance(n.func, ast.Name)
            and n.func.id == "len"
        ):
            return
        if isinstance(n, ast.Name):
            out.add(n.id)
        for child in ast.iter_child_nodes(n):
            visit(child)

    visit(node)
    return out


def _kw(call: ast.Call, name: str) -> Optional[ast.expr]:
    for k in call.keywords:
        if k.arg == name:
            return k.value
    return None


def _str_elts(node: Optional[ast.expr]) -> Set[str]:
    """String elements of a tuple/list/single-string literal."""
    out: Set[str] = set()
    if node is None:
        return out
    elts: Sequence[ast.expr]
    if isinstance(node, (ast.Tuple, ast.List)):
        elts = node.elts
    else:
        elts = [node]
    for e in elts:
        if isinstance(e, ast.Constant) and isinstance(e.value, str):
            out.add(e.value)
    return out


def _int_elts(node: Optional[ast.expr]) -> List[int]:
    out: List[int] = []
    if node is None:
        return out
    elts: Sequence[ast.expr]
    if isinstance(node, (ast.Tuple, ast.List)):
        elts = node.elts
    else:
        elts = [node]
    for e in elts:
        if isinstance(e, ast.Constant) and isinstance(e.value, int):
            out.append(e.value)
    return out


def _jit_call(node: ast.AST) -> Optional[ast.Call]:
    """The jax.jit(...) Call if ``node`` is one (incl. functools.partial
    wrapping), else None."""
    if not isinstance(node, ast.Call):
        return None
    callee = _dotted(node.func)
    if callee in ("jax.jit", "jit"):
        return node
    if callee in ("functools.partial", "partial") and node.args:
        inner = _dotted(node.args[0])
        if inner in ("jax.jit", "jit"):
            return node
    return None


class Rule:
    code = "RL00"
    name = "base"

    def in_scope(self, relpath: str) -> bool:
        return True

    def run(self, ctx: Context) -> Iterator[Violation]:
        for mod in ctx.modules:
            if self.in_scope(mod.relpath) or _is_fixture(mod.relpath):
                yield from self.check(mod, ctx)

    def check(self, mod: Module, ctx: Context) -> Iterator[Violation]:
        return iter(())


# --------------------------------------------------------------- RL01
class TracedBranchRule(Rule):
    """Python control flow / host conversions on traced values.

    A function body is "traced" when the function is jit-decorated or
    passed by name to jax.jit / jax.vmap / jax.lax.scan / jax.lax.cond
    / checkify.checkify, or defined inside a traced function. Within a
    traced body, parameters (minus jit static_argnames/static_argnums)
    seed a taint set that propagates through assignments; `if`/`while`
    tests, float()/int()/bool() calls and .item() on tainted names are
    tracer leaks: they force a concrete value at trace time (works once,
    then produces a ConcretizationTypeError or — worse — silently bakes
    in the first traced value).
    """

    code = "RL01"
    name = "traced-branch"

    _TRACING_CALLEES = (
        "jax.jit", "jit",
        "jax.vmap", "vmap",
        "jax.lax.scan", "lax.scan",
        "jax.lax.cond", "lax.cond",
        "jax.lax.while_loop", "lax.while_loop",
        "jax.lax.fori_loop", "lax.fori_loop",
        "checkify.checkify",
    )

    def check(self, mod: Module, ctx: Context) -> Iterator[Violation]:
        # pass 1: function names handed to tracing call sites
        handed: Set[str] = set()
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and _dotted(node.func) in self._TRACING_CALLEES:
                for arg in node.args:
                    if isinstance(arg, ast.Name):
                        handed.add(arg.id)
                    if isinstance(arg, ast.Lambda):
                        yield from self._check_fn(mod, arg, set())
        # pass 2: decorated or handed-off function defs
        for node in ast.walk(mod.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            static: Set[str] = set()
            traced = node.name in handed
            for deco in node.decorator_list:
                jc = _jit_call(deco)
                if jc is not None:
                    traced = True
                    static |= self._static_params(node, jc)
                elif _dotted(deco) in ("jax.jit", "jit"):
                    traced = True
            if traced:
                yield from self._check_fn(mod, node, static)

    @staticmethod
    def _static_params(fn: ast.FunctionDef, jit_call: ast.Call) -> Set[str]:
        static = _str_elts(_kw(jit_call, "static_argnames"))
        params = [a.arg for a in fn.args.posonlyargs + fn.args.args]
        for i in _int_elts(_kw(jit_call, "static_argnums")):
            if 0 <= i < len(params):
                static.add(params[i])
        return static

    def _check_fn(self, mod, fn, static: Set[str]) -> Iterator[Violation]:
        if isinstance(fn, ast.Lambda):
            return  # lambdas can't contain statements
        args = fn.args
        params = [
            a.arg
            for a in args.posonlyargs + args.args + args.kwonlyargs
            if a.arg not in static
        ]
        tainted = set(params)
        # one forward propagation pass: x = f(tainted) taints x unless
        # only static metadata (.shape etc.) of the tainted value flows in
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and _value_names(node.value) & tainted:
                for tgt in node.targets:
                    tainted |= {
                        n.id
                        for n in ast.walk(tgt)
                        if isinstance(n, ast.Name)
                    }
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While)):
                hot = self._traced_test(node.test, tainted, static)
                if hot:
                    kind = "if" if isinstance(node, ast.If) else "while"
                    yield Violation(
                        mod.relpath, node.lineno, node.col_offset + 1,
                        self.code,
                        f"Python `{kind}` on traced value(s) {hot} inside a "
                        "traced function",
                        "use jnp.where / lax.cond / lax.select",
                    )
            if isinstance(node, ast.Call):
                callee = _dotted(node.func)
                if callee in ("float", "int", "bool") and node.args:
                    if _value_names(node.args[0]) & tainted:
                        yield Violation(
                            mod.relpath, node.lineno, node.col_offset + 1,
                            self.code,
                            f"`{callee}()` forces a traced value to a Python "
                            "scalar inside a traced function",
                            "keep it an array; convert after jax.device_get",
                        )
                if (
                    isinstance(node.func, ast.Attribute)
                    and node.func.attr == "item"
                    and _value_names(node.func.value) & tainted
                ):
                    yield Violation(
                        mod.relpath, node.lineno, node.col_offset + 1,
                        self.code,
                        "`.item()` on a traced value inside a traced function",
                        "keep it an array; convert after jax.device_get",
                    )

    @staticmethod
    def _traced_test(test: ast.expr, tainted: Set[str], static: Set[str]):
        # `x is None` / `x is not None` dispatches on Python structure
        # (static at trace time), not the traced value — allowed.
        if isinstance(test, ast.Compare) and all(
            isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops
        ):
            return set()
        names = _value_names(test)
        return sorted(names & tainted - static)


# --------------------------------------------------------------- RL02
class DonatedUseRule(Rule):
    """Use of a donated buffer after the donating call.

    Detects both shapes the repo uses: a direct
    ``j = jax.jit(f, donate_argnums=...)`` followed by ``j(a, b)``, and
    the engine's donating-factory pattern — a function that builds the
    donating jit and returns a lambda closing over it
    (``core/episode.py::_compiled_runner``) — whose call sites look like
    ``_compiled_runner(spec)(batch, tables)``. After the donating call,
    loads of the donated argument names are flagged until the name is
    reassigned (the classic ``params, _ = step(params, ...)`` loop stays
    clean because the call statement itself stores the name).
    """

    code = "RL02"
    name = "donated-use"

    def check(self, mod: Module, ctx: Context) -> Iterator[Violation]:
        donating_names, factories = self._donators(mod.tree)
        for fn in ast.walk(mod.tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._check_body(mod, fn, donating_names, factories)

    @staticmethod
    def _donators(tree: ast.Module):
        """(name -> donated positions) for jitted callables, and
        (factory function name -> donated positions of the returned
        callable)."""
        donating: dict = {}
        factories: dict = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                jc = _jit_call(node.value)
                tgt = node.targets[0]
                if jc is not None and isinstance(tgt, ast.Name):
                    pos = _int_elts(_kw(jc, "donate_argnums"))
                    if pos:
                        donating[tgt.id] = tuple(pos)
        for node in ast.walk(tree):
            if not isinstance(node, ast.FunctionDef):
                continue
            local: dict = {}
            for sub in ast.walk(node):
                if isinstance(sub, ast.Assign) and len(sub.targets) == 1:
                    jc = _jit_call(sub.value)
                    if jc is not None and isinstance(sub.targets[0], ast.Name):
                        pos = _int_elts(_kw(jc, "donate_argnums"))
                        if pos:
                            local[sub.targets[0].id] = tuple(pos)
            if not local:
                continue
            for sub in ast.walk(node):
                if not isinstance(sub, ast.Return) or sub.value is None:
                    continue
                val = sub.value
                if isinstance(val, ast.Name) and val.id in local:
                    factories[node.name] = local[val.id]
                if isinstance(val, ast.Lambda) and isinstance(val.body, ast.Call):
                    inner = val.body
                    if (
                        isinstance(inner.func, ast.Name)
                        and inner.func.id in local
                    ):
                        lam_params = [a.arg for a in val.args.args]
                        outer: List[int] = []
                        for i in local[inner.func.id]:
                            if i < len(inner.args) and isinstance(
                                inner.args[i], ast.Name
                            ):
                                nm = inner.args[i].id
                                if nm in lam_params:
                                    outer.append(lam_params.index(nm))
                        if outer:
                            factories[node.name] = tuple(outer)
        return donating, factories

    def _check_body(self, mod, fn, donating, factories) -> Iterator[Violation]:
        stmts = list(ast.walk(fn))
        # donating calls in this body: (stmt lineno, donated Name args)
        poisoned: dict = {}  # name -> lineno of donation
        events: List[Tuple[int, str, str]] = []  # (line, kind, name)
        for node in stmts:
            if not isinstance(node, ast.Call):
                continue
            pos: Tuple[int, ...] = ()
            if isinstance(node.func, ast.Name) and node.func.id in donating:
                pos = donating[node.func.id]
            elif (
                isinstance(node.func, ast.Call)
                and isinstance(node.func.func, ast.Name)
                and node.func.func.id in factories
            ):
                pos = factories[node.func.func.id]
            for i in pos:
                if i < len(node.args) and isinstance(node.args[i], ast.Name):
                    events.append((node.lineno, "donate", node.args[i].id))
        if not events:
            return
        for node in stmts:
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    for n in ast.walk(tgt):
                        if isinstance(n, ast.Name):
                            events.append((node.lineno, "store", n.id))
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                if isinstance(node.target, ast.Name):
                    events.append((node.lineno, "store", node.target.id))
            elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                events.append((node.lineno, "load", node.id))
        # source order; at equal line: loads < donate/store (a statement
        # reads its operands before the call donates / the target binds)
        order = {"load": 0, "donate": 1, "store": 2}
        events.sort(key=lambda e: (e[0], order[e[1]]))
        for line, kind, name in events:
            if kind == "donate":
                poisoned[name] = line
            elif kind == "store":
                poisoned.pop(name, None)
            elif kind == "load" and name in poisoned:
                yield Violation(
                    mod.relpath, line, 1, self.code,
                    f"`{name}` was donated to a jit call on line "
                    f"{poisoned[name]} and is read afterwards (its buffer "
                    "may be aliased/invalid)",
                    "reassign from the call result or drop donate_argnums",
                )
                poisoned.pop(name)


# --------------------------------------------------------------- RL03
class NondeterminismRule(Rule):
    """Nondeterminism in benchmark ``results`` writers.

    The repo's contract (EXPERIMENTS.md): the ``results`` block of every
    BENCH_*.json is byte-identical across runs; only the ``engine``
    telemetry block may vary. Wall-clock reads other than
    time.perf_counter (which the telemetry path uses), unseeded RNG, and
    unsorted JSON serialization in the bench writers break that.
    """

    code = "RL03"
    name = "bench-nondeterminism"

    _CLOCKS = (
        "time.time", "time.time_ns", "datetime.now", "datetime.utcnow",
        "datetime.datetime.now", "datetime.datetime.utcnow", "uuid.uuid4",
    )
    _UNSEEDED = (
        "np.random.rand", "np.random.randn", "np.random.randint",
        "np.random.random", "np.random.normal", "np.random.uniform",
        "np.random.choice", "np.random.shuffle", "np.random.permutation",
        "numpy.random.rand", "numpy.random.randn",
        "random.random", "random.randint", "random.choice",
        "random.shuffle", "random.uniform",
    )

    def in_scope(self, relpath: str) -> bool:
        return relpath.startswith("benchmarks/") or relpath.endswith(
            "experiments/schema.py"
        )

    def check(self, mod: Module, ctx: Context) -> Iterator[Violation]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            callee = _dotted(node.func)
            if callee in self._CLOCKS:
                yield Violation(
                    mod.relpath, node.lineno, node.col_offset + 1, self.code,
                    f"wall-clock/nondeterministic source `{callee}` in a "
                    "benchmark results path",
                    "time.perf_counter for telemetry; keep it out of "
                    "`results` blocks",
                )
            elif callee in self._UNSEEDED:
                yield Violation(
                    mod.relpath, node.lineno, node.col_offset + 1, self.code,
                    f"unseeded RNG `{callee}` makes the results block "
                    "run-dependent",
                    "np.random.default_rng(seed) with an explicit seed",
                )
            elif callee.endswith("default_rng") and not node.args:
                yield Violation(
                    mod.relpath, node.lineno, node.col_offset + 1, self.code,
                    "default_rng() without a seed makes the results block "
                    "run-dependent",
                    "pass an explicit seed",
                )
            elif callee in ("json.dump", "json.dumps"):
                sk = _kw(node, "sort_keys")
                if not (isinstance(sk, ast.Constant) and sk.value is True):
                    yield Violation(
                        mod.relpath, node.lineno, node.col_offset + 1,
                        self.code,
                        f"`{callee}` without sort_keys=True is dict-order "
                        "dependent",
                        "sort_keys=True (or route through "
                        "benchmarks.common.emit_json)",
                    )


# --------------------------------------------------------------- RL04
class DtypeDisciplineRule(Rule):
    """Dtype discipline in the fixed-size engine state.

    The episode carry and the incremental dCor state are fixed-size
    f32/i32/bool containers (EXPERIMENTS.md §Episode engine); an
    un-annotated jnp constructor or a float64 leak silently doubles the
    state or — under JAX_ENABLE_X64 — changes results. Also cross-checks
    the carry fields written in ``_init_carry`` against the contract
    tables in core/contracts.py so the static rule and the
    REPRO_CONTRACTS=1 runtime lane can never drift.
    """

    code = "RL04"
    name = "dtype-discipline"

    _ZONE = ("core/episode.py", "core/dcov.py")
    # constructor -> position where dtype may be passed positionally
    _CONSTRUCTORS = {
        "jnp.zeros": 1, "jnp.ones": 1, "jnp.empty": 1, "jnp.eye": 2,
        "jnp.full": 2, "jnp.arange": None, "jnp.linspace": None,
    }
    _F64 = ("jnp.float64", "np.float64", "numpy.float64")

    def in_scope(self, relpath: str) -> bool:
        return relpath.endswith(self._ZONE)

    @classmethod
    def _annotated(cls, node: ast.Call, callee: str) -> bool:
        if _kw(node, "dtype") is not None:
            return True
        pos = cls._CONSTRUCTORS[callee]
        return pos is not None and len(node.args) > pos

    def check(self, mod: Module, ctx: Context) -> Iterator[Violation]:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                callee = _dotted(node.func)
                if callee in self._CONSTRUCTORS and not self._annotated(
                    node, callee
                ):
                    yield Violation(
                        mod.relpath, node.lineno, node.col_offset + 1,
                        self.code,
                        f"`{callee}` without an explicit dtype in the "
                        "fixed-size engine state",
                        "annotate dtype=jnp.float32 / jnp.int32",
                    )
            if isinstance(node, ast.Attribute) and _dotted(node) in self._F64:
                yield Violation(
                    mod.relpath, node.lineno, node.col_offset + 1, self.code,
                    "explicit float64 in the engine state (implicit "
                    "promotion doubles the fixed-size carry)",
                    "engine state is float32; convert at the boundary",
                )
        if mod.relpath.endswith("core/episode.py") and not _is_fixture(mod.relpath):
            yield from self._contract_cross_check(mod, ctx)

    def _contract_cross_check(self, mod: Module, ctx: Context):
        contracts = ctx.module("src/repro/core/contracts.py")
        if contracts is None:
            yield Violation(
                mod.relpath, 1, 1, self.code,
                "core/contracts.py not found — the carry has no "
                "shape/dtype contract table",
                "add core/contracts.py (REPRO_CONTRACTS=1 lane)",
            )
            return
        table: Set[str] = set()
        for node in ast.walk(contracts.tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                tgt, val = node.targets[0], node.value
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                tgt, val = node.target, node.value
            else:
                continue
            if (
                isinstance(tgt, ast.Name)
                and tgt.id.endswith("_CONTRACT")
                and isinstance(val, ast.Dict)
            ):
                for k in val.keys:
                    if isinstance(k, ast.Constant) and isinstance(k.value, str):
                        table.add(k.value)
        init = None
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.FunctionDef) and node.name == "_init_carry":
                init = node
                break
        if init is None:
            return
        for node in ast.walk(init):
            keys: List[Tuple[str, int, int]] = []
            if isinstance(node, ast.Dict):
                for k in node.keys:
                    if isinstance(k, ast.Constant) and isinstance(k.value, str):
                        keys.append((k.value, k.lineno, k.col_offset))
            if (
                isinstance(node, ast.Assign)
                and isinstance(node.targets[0], ast.Subscript)
                and isinstance(node.targets[0].slice, ast.Constant)
                and isinstance(node.targets[0].slice.value, str)
            ):
                s = node.targets[0].slice
                keys.append((s.value, s.lineno, s.col_offset))
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "update"
            ):
                for k in node.keywords:
                    if k.arg is not None:
                        keys.append((k.arg, k.value.lineno, k.value.col_offset))
            for key, line, col in keys:
                if key not in table:
                    yield Violation(
                        mod.relpath, line, col + 1, self.code,
                        f"carry field '{key}' is not covered by any "
                        "*_CONTRACT table in core/contracts.py",
                        "add it to the matching contract table",
                    )


# --------------------------------------------------------------- RL05
class InterpretRoutingRule(Rule):
    """Pallas kernels must route interpret-mode through
    repro.kernels.runtime.default_interpret (the harness-side view is
    benchmarks.common.pallas_interpret — same parser underneath), never
    derive it locally: a hardcoded ``interpret=True`` default silently
    pins a kernel to the interpreter on TPU; a local env read forks the
    PALLAS_INTERPRET parsing."""

    code = "RL05"
    name = "interpret-routing"

    _CANONICAL = "src/repro/kernels/runtime.py"

    def in_scope(self, relpath: str) -> bool:
        return (
            relpath.startswith("src/repro/kernels/")
            and relpath != self._CANONICAL
        )

    def check(self, mod: Module, ctx: Context) -> Iterator[Violation]:
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.Lambda)):
                yield from self._check_defaults(mod, node)
            if not isinstance(node, ast.Call):
                continue
            callee = _dotted(node.func)
            val = _kw(node, "interpret")
            if (
                callee.endswith("pallas_call")
                and isinstance(val, ast.Constant)
                and isinstance(val.value, bool)
            ):
                yield Violation(
                    mod.relpath, val.lineno, val.col_offset + 1, self.code,
                    f"pallas_call(interpret={val.value}) hardcodes the "
                    "execution mode",
                    "thread an interpret param defaulting to "
                    "repro.kernels.runtime.default_interpret()",
                )
            if callee in ("jax.default_backend", "default_backend"):
                yield Violation(
                    mod.relpath, node.lineno, node.col_offset + 1, self.code,
                    "kernel derives interpret mode from the backend itself",
                    "call repro.kernels.runtime.default_interpret()",
                )
            if (
                callee in ("os.environ.get", "os.getenv")
                and node.args
                and isinstance(node.args[0], ast.Constant)
                and node.args[0].value == "PALLAS_INTERPRET"
            ):
                yield Violation(
                    mod.relpath, node.lineno, node.col_offset + 1, self.code,
                    "kernel parses PALLAS_INTERPRET itself",
                    "route through repro.kernels.runtime.default_interpret "
                    "(single parser: repro.envflags)",
                )

    def _check_defaults(self, mod, fn) -> Iterator[Violation]:
        args = fn.args
        named = args.posonlyargs + args.args
        defaults = args.defaults
        for a, d in zip(named[len(named) - len(defaults):], defaults):
            if (
                a.arg == "interpret"
                and isinstance(d, ast.Constant)
                and isinstance(d.value, bool)
            ):
                yield Violation(
                    mod.relpath, d.lineno, d.col_offset + 1, self.code,
                    f"`interpret={d.value}` default pins the execution mode",
                    "default to None and resolve via "
                    "repro.kernels.runtime.default_interpret()",
                )
        for a, d in zip(args.kwonlyargs, args.kw_defaults):
            if (
                a.arg == "interpret"
                and isinstance(d, ast.Constant)
                and isinstance(d.value, bool)
            ):
                yield Violation(
                    mod.relpath, d.lineno, d.col_offset + 1, self.code,
                    f"`interpret={d.value}` default pins the execution mode",
                    "default to None and resolve via "
                    "repro.kernels.runtime.default_interpret()",
                )


# --------------------------------------------------------------- RL06
class DeadModuleRule(Rule):
    """Dead/unreachable module detection over src/repro.

    Roots: every linted file outside src/ (tests, benchmarks), every
    examples/*.py (examples are entry points even when not linted), and
    every src module with an ``if __name__ == "__main__"`` guard. A
    src/repro module no root can reach through the import graph is dead
    code.
    """

    code = "RL06"
    name = "dead-module"

    def run(self, ctx: Context) -> Iterator[Violation]:
        from tools.repro_lint.importgraph import dead_modules

        src_root = ctx.repo_root / "src"
        if not (src_root / "repro").is_dir():
            return
        extra_roots = [
            m.path for m in ctx.modules
            if not m.relpath.startswith("src/") and not _is_fixture(m.relpath)
        ]
        examples = ctx.repo_root / "examples"
        if examples.is_dir():
            extra_roots.extend(sorted(examples.rglob("*.py")))
        for path in dead_modules(src_root, "repro", extra_roots):
            rel = path.relative_to(ctx.repo_root).as_posix()
            yield Violation(
                rel, 1, 1, self.code,
                "module is unreachable from every entry point (tests, "
                "benchmarks, examples, __main__ guards)",
                "delete it or import it from a live module",
            )


# --------------------------------------------------------------- RL07
def _contract_spec_sets(ctx: Context) -> Dict[str, Set[str]]:
    """field name -> the set of jaxtyping spec strings any *_CONTRACT
    table in core/contracts.py assigns it. A set, not a single spec:
    some fields legitimately appear in several containers with
    different shapes (``p_budget`` is a scalar in the drift carry and a
    (B,) column in the fleet batch)."""
    table: Dict[str, Set[str]] = {}
    contracts = ctx.module("src/repro/core/contracts.py")
    if contracts is not None:
        tree = contracts.tree
    else:
        # single-file invocations (golden fixtures, editor integration)
        # don't load contracts.py as a linted module — read it directly
        path = ctx.repo_root / "src" / "repro" / "core" / "contracts.py"
        if not path.is_file():
            return table
        tree = ast.parse(path.read_text())
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt, val = node.targets[0], node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            tgt, val = node.target, node.value
        else:
            continue
        if (
            isinstance(tgt, ast.Name)
            and tgt.id.endswith("_CONTRACT")
            and isinstance(val, ast.Dict)
        ):
            for k, v in zip(val.keys, val.values):
                if (
                    isinstance(k, ast.Constant)
                    and isinstance(k.value, str)
                    and isinstance(v, ast.Constant)
                    and isinstance(v.value, str)
                ):
                    table.setdefault(k.value, set()).add(v.value)
    return table


class DocstringContractRule(Rule):
    """Public API docs must exist and must not lie about shapes.

    The format-zone modules (the ruff-format-clean directories: core/,
    serving/, experiments/, device/) are the repo's documented surface.
    Two invariants (see docs/ARCHITECTURE.md):

    - every module-level public function carries a docstring;
    - every jaxtyping-style field spec quoted in a docstring
      (``hist_sm: Float32[Array, "T+W D+4"]``) agrees with the
      *_CONTRACT tables in core/contracts.py — a stale shape in prose
      is worse than no shape, because readers trust it over the code.
    """

    code = "RL07"
    name = "docstring-contract"

    _ZONE = (
        "src/repro/core/",
        "src/repro/serving/",
        "src/repro/experiments/",
        "src/repro/device/",
    )
    _SPEC = re.compile(
        r"(\w+)\s*:\s*(Float32|Float64|Int32|Bool)\s*"
        r'\[\s*Array\s*,\s*"([^"]*)"\s*\]'
    )

    def in_scope(self, relpath: str) -> bool:
        return relpath.startswith(self._ZONE)

    def check(self, mod: Module, ctx: Context) -> Iterator[Violation]:
        for node in mod.tree.body:
            if (
                isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                and not node.name.startswith("_")
                and ast.get_docstring(node) is None
            ):
                yield Violation(
                    mod.relpath, node.lineno, node.col_offset + 1, self.code,
                    f"public function `{node.name}` has no docstring",
                    "one sentence on inputs/outputs (array shapes included)",
                )
        table = _contract_spec_sets(ctx)
        if not table:
            return
        for node in ast.walk(mod.tree):
            if not isinstance(
                node, (ast.Module, ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
            ):
                continue
            doc = ast.get_docstring(node)
            if not doc:
                continue
            line = getattr(node, "lineno", 1)
            # docstrings wrap mid-spec; normalize whitespace before matching
            for m in self._SPEC.finditer(" ".join(doc.split())):
                field, dtype, dims = m.groups()
                want = table.get(field)
                if want is None:
                    continue  # not a contracted field; prose is free
                got = f'{dtype}[Array, "{dims}"]'
                if got not in want:
                    yield Violation(
                        mod.relpath, line, 1, self.code,
                        f"docstring says `{field}: {got}` but "
                        f"core/contracts.py says {sorted(want)}",
                        "update the docstring (or the contract table) so "
                        "they agree",
                    )


# --------------------------------------------------------------- RL08
class SwallowedExceptRule(Rule):
    """Serving-layer fault paths must not swallow failures silently.

    The fault-tolerance contract (docs/ARCHITECTURE.md §Fault seam)
    routes every runtime/controller failure through an *accounted*
    path: the MAD gate rejects it, the watchdog counts it, or the
    actuation verifier retries it. A ``try`` that catches and discards
    an exception removes the failure from all three ledgers — the
    fleet then scores a faulted twin as healthy. Two shapes flagged:

    - bare ``except:`` (also ``except BaseException:``) — catches
      KeyboardInterrupt/SystemExit and hides programming errors;
    - any handler whose body is only ``pass``/``...``/``continue`` —
      typed or not, the failure vanishes without a log line, counter
      bump, or re-raise.

    Scoped to src/repro/serving/ where the degradation ledger lives.
    """

    code = "RL08"
    name = "swallowed-except"

    def in_scope(self, relpath: str) -> bool:
        return relpath.startswith("src/repro/serving/")

    @staticmethod
    def _is_bare(handler: ast.ExceptHandler) -> bool:
        if handler.type is None:
            return True
        return _dotted(handler.type) in ("BaseException", "builtins.BaseException")

    @staticmethod
    def _is_swallowed(handler: ast.ExceptHandler) -> bool:
        for stmt in handler.body:
            if isinstance(stmt, (ast.Pass, ast.Continue)):
                continue
            if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Constant):
                continue  # `...` or a stray string literal
            return False
        return True

    def check(self, mod: Module, ctx: Context) -> Iterator[Violation]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if self._is_bare(node):
                yield Violation(
                    mod.relpath, node.lineno, node.col_offset + 1, self.code,
                    "bare `except:` in the serving layer hides faults from "
                    "the degradation ledger",
                    "catch the specific exception and count/log/re-raise it",
                )
            elif self._is_swallowed(node):
                yield Violation(
                    mod.relpath, node.lineno, node.col_offset + 1, self.code,
                    "exception handler silently swallows the failure "
                    "(body is only pass/.../continue)",
                    "bump a fault counter, log, or re-raise so the watchdog "
                    "and actuation verifier can see it",
                )


ALL_RULES: Tuple[Rule, ...] = (
    TracedBranchRule(),
    DonatedUseRule(),
    NondeterminismRule(),
    DtypeDisciplineRule(),
    InterpretRoutingRule(),
    DeadModuleRule(),
    DocstringContractRule(),
    SwallowedExceptRule(),
)
