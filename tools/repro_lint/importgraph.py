"""Import-graph reachability over a src package (rule RL06).

Generic over the package name so the golden fixture tree under
tests/lint_fixtures/rl06_tree/ exercises the same code path as the real
``src/repro`` scan. Stdlib only.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, Iterable, List, Set


def package_modules(src_root: Path, package: str) -> Dict[str, Path]:
    """dotted module name -> file for every .py under src_root/package."""
    out: Dict[str, Path] = {}
    pkg_dir = src_root / package
    for f in sorted(pkg_dir.rglob("*.py")):
        if "__pycache__" in f.parts:
            continue
        rel = f.relative_to(src_root)
        if f.name == "__init__.py":
            name = ".".join(rel.parent.parts)
        else:
            name = ".".join(rel.with_suffix("").parts)
        out[name] = f
    return out


def _module_edges(path: Path, modules: Dict[str, Path], package: str) -> Set[str]:
    """Modules (by dotted name) that importing ``path`` reaches."""
    try:
        tree = ast.parse(path.read_text())
    except (SyntaxError, OSError):
        return set()
    edges: Set[str] = set()

    def add(name: str) -> None:
        # importing a.b.c executes a/__init__ and a.b/__init__ too
        parts = name.split(".")
        for i in range(1, len(parts) + 1):
            prefix = ".".join(parts[:i])
            if prefix in modules:
                edges.add(prefix)

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name.split(".")[0] == package:
                    add(alias.name)
        elif isinstance(node, ast.ImportFrom) and node.level == 0:
            mod = node.module or ""
            if mod.split(".")[0] != package:
                continue
            add(mod)
            for alias in node.names:
                # `from pkg.sub import name` where name is a submodule
                cand = f"{mod}.{alias.name}"
                if cand in modules:
                    add(cand)
    return edges


def has_main_guard(path: Path) -> bool:
    try:
        tree = ast.parse(path.read_text())
    except (SyntaxError, OSError):
        return False
    for node in tree.body:
        if (
            isinstance(node, ast.If)
            and isinstance(node.test, ast.Compare)
            and isinstance(node.test.left, ast.Name)
            and node.test.left.id == "__name__"
        ):
            return True
    return False


def dead_modules(
    src_root: Path, package: str, extra_roots: Iterable[Path]
) -> List[Path]:
    """Package modules unreachable from any root. Roots: the
    ``extra_roots`` files (tests/benchmarks/examples) plus every package
    module with a ``__main__`` guard (a script is its own entry point).
    Package ``__init__`` files are reachable whenever any module below
    them is (importing the module executes the ancestor inits)."""
    modules = package_modules(src_root, package)
    reached: Set[str] = set()
    frontier: List[str] = []

    def mark(name: str) -> None:
        if name not in reached and name in modules:
            reached.add(name)
            frontier.append(name)
            # ancestor packages execute on import
            parts = name.split(".")
            for i in range(1, len(parts)):
                mark(".".join(parts[:i]))

    for root in extra_roots:
        for name in _module_edges(Path(root), modules, package):
            mark(name)
    for name, path in modules.items():
        if has_main_guard(path):
            mark(name)
    while frontier:
        name = frontier.pop()
        for dep in _module_edges(modules[name], modules, package):
            mark(dep)
    return sorted(
        path for name, path in modules.items() if name not in reached
    )
