"""CLI: ``python -m tools.repro_lint src tests benchmarks``.

Output is ruff-style ``path:line:col: CODE message [fix: hint]`` so the
CI lint job renders both linters identically. Exit 1 on any violation.
"""
from __future__ import annotations

import argparse
import sys

from tools.repro_lint.engine import lint_paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="JAX-aware static analysis for the repro engine "
        "invariants (rules RL01-RL08; see EXPERIMENTS.md §Static analysis)",
    )
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument(
        "--select",
        help="comma-separated rule codes to run (default: all)",
    )
    ap.add_argument(
        "--include-fixtures",
        action="store_true",
        help="lint tests/lint_fixtures/ too (the golden bad snippets)",
    )
    ns = ap.parse_args(argv)
    select = (
        {c.strip() for c in ns.select.split(",") if c.strip()}
        if ns.select
        else None
    )
    violations = lint_paths(
        ns.paths, select=select, include_fixtures=ns.include_fixtures
    )
    for v in violations:
        print(v.render())
    n = len(violations)
    if n:
        print(f"repro-lint: {n} violation{'s' if n != 1 else ''}")
        return 1
    print("repro-lint: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
