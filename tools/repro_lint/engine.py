"""repro-lint driver: file discovery, disable-pragma handling, ruff-style
output. Rules live in tools/repro_lint/rules.py; the import graph used
by RL06 in tools/repro_lint/importgraph.py. Stdlib only — the CI lint
job runs this without jax installed.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set

# repo root = parent of tools/ — the tool is path-independent of cwd
REPO_ROOT = Path(__file__).resolve().parents[2]

# `# repro-lint: disable=RL01` or `disable=RL01,RL04 — reason text`
_PRAGMA = re.compile(
    r"repro-lint:\s*disable=([A-Z0-9,\s]+?)(?:\s*(?:—|–|--|-)\s+(.+))?$"
)

# golden bad-snippet fixtures are excluded from directory walks (they
# exist to violate rules) but still lintable when named explicitly
FIXTURE_DIR = "lint_fixtures"


@dataclasses.dataclass(frozen=True)
class Violation:
    path: str  # repo-relative posix path
    line: int
    col: int  # 1-based, ruff-style
    code: str
    message: str
    hint: str = ""

    def render(self) -> str:
        text = f"{self.path}:{self.line}:{self.col}: {self.code} {self.message}"
        if self.hint:
            text += f" [fix: {self.hint}]"
        return text


class Module:
    """One parsed source file plus its disable pragmas."""

    def __init__(self, path: Path, relpath: str, source: str):
        self.path = path
        self.relpath = relpath
        self.source = source
        self.tree = ast.parse(source, filename=relpath)
        # line -> codes disabled on that line ("*" never used: codes only)
        self.disables: Dict[int, Set[str]] = {}
        self.pragma_errors: List[Violation] = []
        self._scan_pragmas()

    def _scan_pragmas(self) -> None:
        # tokenize so pragmas inside string literals don't count
        try:
            tokens = tokenize.generate_tokens(io.StringIO(self.source).readline)
            comments = [t for t in tokens if t.type == tokenize.COMMENT]
        except tokenize.TokenizeError:
            return
        for tok in comments:
            m = _PRAGMA.search(tok.string)
            if not m:
                continue
            codes = {c.strip() for c in m.group(1).split(",") if c.strip()}
            reason = (m.group(2) or "").strip()
            line = tok.start[0]
            if not reason:
                self.pragma_errors.append(
                    Violation(
                        self.relpath,
                        line,
                        tok.start[1] + 1,
                        "RL00",
                        "disable pragma without a reason",
                        'write "# repro-lint: disable=RLxx — why it is safe"',
                    )
                )
                continue
            self.disables.setdefault(line, set()).update(codes)
            # a standalone comment line disables the next code line too
            stripped = self.lines[line - 1].strip() if line <= len(self.lines) else ""
            if stripped.startswith("#"):
                self.disables.setdefault(line + 1, set()).update(codes)

    @property
    def lines(self) -> List[str]:
        return self.source.splitlines()

    def disabled(self, line: int, code: str) -> bool:
        return code in self.disables.get(line, ())


class Context:
    """Everything a rule can see: the parsed modules plus the repo root
    (RL06 walks src/repro and examples/ from here regardless of which
    paths were passed on the command line)."""

    def __init__(self, modules: List[Module], repo_root: Path = REPO_ROOT):
        self.modules = modules
        self.repo_root = repo_root

    def module(self, relpath: str) -> Optional[Module]:
        for m in self.modules:
            if m.relpath == relpath:
                return m
        return None


def _collect_files(paths: Iterable[str], include_fixtures: bool) -> List[Path]:
    out: List[Path] = []
    for raw in paths:
        p = Path(raw)
        if not p.is_absolute():
            p = REPO_ROOT / p
        if p.is_dir():
            for f in sorted(p.rglob("*.py")):
                parts = f.parts
                if "__pycache__" in parts:
                    continue
                if FIXTURE_DIR in parts and not include_fixtures:
                    continue
                out.append(f)
        elif p.suffix == ".py":
            out.append(p)  # explicit file: fixtures included on purpose
    return out


def load_modules(
    paths: Iterable[str], include_fixtures: bool = False
) -> tuple[List[Module], List[Violation]]:
    modules: List[Module] = []
    errors: List[Violation] = []
    for f in _collect_files(paths, include_fixtures):
        try:
            rel = f.resolve().relative_to(REPO_ROOT).as_posix()
        except ValueError:
            rel = f.as_posix()
        try:
            modules.append(Module(f, rel, f.read_text()))
        except SyntaxError as e:
            errors.append(
                Violation(rel, e.lineno or 1, (e.offset or 0) + 1, "RL00",
                          f"syntax error: {e.msg}")
            )
    return modules, errors


def lint_paths(
    paths: Iterable[str],
    select: Optional[Set[str]] = None,
    include_fixtures: bool = False,
) -> List[Violation]:
    """Run every rule (or the ``select`` subset) over ``paths`` and
    return the surviving violations, sorted for stable output."""
    from tools.repro_lint.rules import ALL_RULES

    modules, errors = load_modules(paths, include_fixtures)
    ctx = Context(modules)
    raw: List[Violation] = list(errors)
    by_rel = {m.relpath: m for m in modules}
    for rule in ALL_RULES:
        if select and rule.code not in select:
            continue
        raw.extend(rule.run(ctx))
    out: List[Violation] = []
    for v in raw:
        mod = by_rel.get(v.path)
        if v.code != "RL00" and mod is not None and mod.disabled(v.line, v.code):
            continue
        out.append(v)
    for m in modules:
        if select is None or "RL00" in select:
            out.extend(m.pragma_errors)
    return sorted(set(out), key=lambda v: (v.path, v.line, v.col, v.code))
