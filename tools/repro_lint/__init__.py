"""repro-lint: JAX-aware static analysis for this repo's engine invariants.

Stdlib-only (``ast`` + ``tokenize``) so the CI lint job can run it
without installing jax. Rule catalog:

  RL01  tracer leak — Python branching / float() / bool() / .item() on a
        traced value inside a jit or lax.scan body
  RL02  use of a donated buffer after a donate_argnums call
  RL03  nondeterminism in benchmark ``results`` writers (wall-clock,
        unseeded RNG, unsorted JSON serialization)
  RL04  dtype discipline in the fixed-size engine state (un-annotated
        array constructors, float64 promotion, carry fields missing from
        core/contracts.py)
  RL05  Pallas kernels deriving ``interpret=`` themselves instead of
        routing through repro.kernels.runtime.default_interpret
  RL06  dead module — unreachable in the import graph over src/repro
  RL07  docstring contract — public format-zone functions without a
        docstring, and docstring shape specs that disagree with the
        *_CONTRACT tables in core/contracts.py
  RL08  swallowed except — bare ``except:`` or handlers whose body is
        only pass/.../continue in src/repro/serving/, which hide
        faults from the degradation ledger

Escape hatch: ``# repro-lint: disable=RLxx — reason`` on the flagged
line (or the comment line directly above it). The reason is mandatory;
a bare disable is itself an RL00 violation. See EXPERIMENTS.md §Static
analysis for the full catalog and policy.
"""
from tools.repro_lint.engine import Violation, lint_paths  # noqa: F401
