"""Doc-consistency walker: every fenced ``python`` block in README.md,
EXPERIMENTS.md and docs/*.md must at least compile, and every import it
names must resolve against the live tree — so renaming a module or a
public symbol breaks CI instead of silently stranding the prose
(EXPERIMENTS.md §Static analysis).

Blocks are compiled, not executed: only their top-level ``import`` /
``from … import …`` statements run, so a documented benchmark
invocation never fires during the check.

    PYTHONPATH=src python -m tools.check_docs
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
_FENCE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)


def doc_files() -> list[Path]:
    out = [REPO_ROOT / "README.md", REPO_ROOT / "EXPERIMENTS.md"]
    docs = REPO_ROOT / "docs"
    if docs.is_dir():
        out.extend(sorted(docs.glob("*.md")))
    return [p for p in out if p.is_file()]


def check_block(name: str, source: str, errors: list[str]) -> None:
    try:
        tree = ast.parse(source, filename=name)
    except SyntaxError as e:
        errors.append(f"{name}: syntax error at line {e.lineno}: {e.msg}")
        return
    for node in tree.body:
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            stmt = ast.get_source_segment(source, node) or "<import>"
            try:
                exec(compile(ast.Module([node], []), name, "exec"), {})
            except Exception as e:
                errors.append(f"{name}: `{stmt}` failed: {e!r}")


def main() -> int:
    errors: list[str] = []
    blocks = 0
    for path in doc_files():
        rel = path.relative_to(REPO_ROOT).as_posix()
        for i, m in enumerate(_FENCE.finditer(path.read_text())):
            blocks += 1
            # fence offset -> real line numbers in the error name
            line = path.read_text()[: m.start(1)].count("\n") + 1
            check_block(f"{rel}:{line} (block {i + 1})", m.group(1), errors)
    for e in errors:
        print(f"  - {e}", file=sys.stderr)
    if errors:
        print(f"check_docs: FAILED ({len(errors)} broken blocks)", file=sys.stderr)
        return 1
    print(f"check_docs: {blocks} fenced python blocks across "
          f"{len(doc_files())} docs compile and import cleanly")
    return 0


if __name__ == "__main__":
    sys.exit(main())
